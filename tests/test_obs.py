"""The flight recorder (repro.obs): device metrics, spans, run reports.

The PR-7 acceptance bars, in test form:

* telemetry changes NOTHING about training — the fused trajectory with
  device metrics on is bit-identical to the plain one, and the loss
  instrumentation (``with_loss``) leaves the parameter stream untouched;
* the fused hot loop stays clean with collect on — zero host transfers
  inside a chunk (the one offload happens at the boundary) and <= 2
  fused compiles;
* the on-device byte/loss/codec metrics agree exactly with the wire
  (repro.comm) ground truth they mirror;
* the events.jsonl schema is a golden contract, and the report CLI
  renders/refuses it correctly.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from _trace_guards import assert_compiles, assert_no_transfers
from repro.comm import wire
from repro.config import FedConfig, ObsConfig, ScbfConfig, TrainConfig
from repro.core.scbf import run_federated
from repro.data.medical import generate_cohort
from repro.fed.engine import make_engine
from repro.fed.scheduler import make_scheduler
from repro.models.mlp_net import init_mlp
from repro.obs import (EVENT_SCHEMA, Recorder, get_recorder, metrics as obsm,
                       recording, span, to_chrome_trace, trace as obstrace)
from repro.obs import report as obs_report


@pytest.fixture(scope="module")
def cohort():
    return generate_cohort(num_admissions=800, num_medicines=40,
                           num_risk_medicines=15, num_interactions=4, seed=0)


FEATS = (40, 16, 4, 1)


def _tcfg(fuse: int, loops: int = 4, K: int = 5, obs=None, **scbf_kw):
    return TrainConfig(
        learning_rate=0.05, global_loops=loops, local_batch_size=64,
        local_epochs=1, eval_every=1,
        obs=obs or ObsConfig(),
        scbf=ScbfConfig(upload_rate=0.1, num_clients=K, **scbf_kw),
        fed=FedConfig(fuse_rounds=fuse))


def _params_bitwise_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# trace primitives
# ---------------------------------------------------------------------------

def test_span_measures_without_recorder():
    assert get_recorder() is None
    with span("anything", foo=1) as sp:
        sum(range(1000))
    assert sp.elapsed > 0.0          # the one wall-clock source always works


def test_recorder_event_log_and_counters(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with recording(path) as rec:
        assert get_recorder() is rec
        obstrace.event("custom", value=3)
        with span("work", n=2):
            pass
        obstrace.count("host_offloads")
    assert get_recorder() is None
    assert rec.counters["events"] == 2          # custom + the span event
    assert rec.counters["spans"] == 1
    assert rec.counters["host_offloads"] == 1
    events = obs_report.read_events(path)
    assert events[0]["ev"] == "meta"
    assert events[0]["schema"] == EVENT_SCHEMA
    kinds = [e["ev"] for e in events]
    assert kinds == ["meta", "custom", "span"]
    assert all(e["ts"] >= 0 for e in events)


def test_events_noop_without_recorder():
    before = Recorder()                  # unrelated, inactive
    obstrace.event("dropped")
    obstrace.count("dropped")
    assert len(before.events) == 1       # only its own meta


def test_chrome_trace_export():
    rec = Recorder()
    rec.event("round", loop=0)
    with rec.span("chunk", rounds=2):
        pass
    trace = to_chrome_trace(rec.events)
    phases = {e["name"]: e["ph"] for e in trace["traceEvents"]}
    assert phases == {"round": "i", "chunk": "X"}
    slice_ = next(e for e in trace["traceEvents"] if e["ph"] == "X")
    assert slice_["ts"] >= 0 and slice_["dur"] >= 0
    assert slice_["args"]["rounds"] == 2


def test_roundplan_telemetry_fields():
    sched = make_scheduler(FedConfig(mode="sync"), num_clients=8, seed=0)
    t = sched.plan(0).telemetry()
    assert set(t) == {"sampled", "dropped", "stragglers",
                      "staleness_mean", "staleness_max"}
    assert t["staleness_mean"] == 0.0 and t["staleness_max"] == 0


def test_codec_breakdown_stable_keys():
    out = wire.codec_breakdown([])
    assert set(out) == set(wire.CODECS)
    assert all(v == 0 for v in out.values())


# ---------------------------------------------------------------------------
# device metrics vs wire ground truth
# ---------------------------------------------------------------------------

def _tiny_engine(K=6, n=32, d=12, batch=16, seed=0):
    rng = np.random.default_rng(seed)
    clients = [((rng.random((n, d)) < 0.3).astype(np.float32),
                (rng.random(n) < 0.5).astype(np.float32))
               for _ in range(K)]
    eng = make_engine("batched", clients, batch, epochs=1)
    params = init_mlp((d, 8, 4, 1), jax.random.PRNGKey(1))
    return eng, params, ScbfConfig(upload_rate=0.25, num_clients=K)


def _keys(key, p):
    key, kc, ks, kd = jax.random.split(key, 4)
    return key, tuple(jax.random.split(k, p) for k in (kc, ks, kd))


def test_device_metrics_match_wire_truth():
    """The on-device byte accounting IS the wire accounting: sparse
    bytes, per-codec breakdown, and participant count must agree with
    the encoded payloads exactly, not approximately."""
    K = 6
    eng, params, cfg = _tiny_engine(K=K)
    _, (ck, sk, dk) = _keys(jax.random.PRNGKey(0), K)
    payloads, stats, dm = eng.scbf_round(params, np.arange(K), 0.05,
                                         ck, sk, dk, cfg, collect=True)
    assert dm["participants"] == len(payloads) == K
    assert dm["sparse_bytes"] == sum(p.nbytes for p in payloads)
    assert dm["codec_bytes"] == wire.codec_breakdown(payloads)
    assert sum(dm["codec_bytes"].values()) == dm["sparse_bytes"]
    assert dm["train_loss"] > 0.0
    assert len(dm["selected"]) == len(params) and \
        all(s >= 0 for s in dm["selected"])


def test_empty_round_collect_shape():
    eng, params, cfg = _tiny_engine()
    out = eng.scbf_round(params, np.array([], np.int64), 0.05,
                         (), (), (), cfg, collect=True)
    assert out == ([], [], None)


def test_with_loss_leaves_params_bitwise_identical():
    """value_and_grad instrumentation must not perturb training: the
    same round with collect on/off produces the same payload bytes."""
    K = 4
    eng, params, cfg = _tiny_engine(K=K)
    _, (ck, sk, dk) = _keys(jax.random.PRNGKey(3), K)
    plain, _ = eng.scbf_round(params, np.arange(K), 0.05, ck, sk, dk, cfg)
    collected, _, dm = eng.scbf_round(params, np.arange(K), 0.05,
                                      ck, sk, dk, cfg, collect=True)
    assert dm["train_loss"] > 0.0
    for a, b in zip(plain, collected):
        assert a.nbytes == b.nbytes
        for la, lb in zip(a.layers, b.layers):
            assert la.codec == lb.codec
            assert np.array_equal(la.values, lb.values)


# ---------------------------------------------------------------------------
# fused-path hygiene: zero in-chunk transfers, bounded compiles
# ---------------------------------------------------------------------------

def test_fused_collect_chunk_transfer_clean_and_two_compiles():
    """With collect on, a warmed fused chunk still crosses the host
    boundary zero times — the (S,)-stacked MetricsCarry rides the scan
    and offloads ONCE at the chunk boundary — and the whole exercise
    stays <= 2 fused compiles."""
    K, S = 6, 3
    eng, params, cfg = _tiny_engine(K=K)
    B = eng.fused_num_slots(K)
    key = jax.random.PRNGKey(0)
    rows = []
    for _ in range(2 * S):
        key, r = _keys(key, K)
        rows.append(r)

    def plan_for(rows):
        return eng.prepare_fused_plan(
            [np.arange(K)] * S, [0.05] * S, [r[0] for r in rows],
            [r[1] for r in rows], [r[2] for r in rows],
            horizon=S, num_slots=B)

    with assert_compiles(2):
        p1, masked, masks, met = eng.fused_scbf_chunk(
            tuple(params), plan_for(rows[:S]), cfg, collect=True)
        jax.block_until_ready(p1)                       # warmup chunk
        plan2 = plan_for(rows[S:])                      # host→device here
        with assert_no_transfers():
            out = eng.fused_scbf_chunk(p1, plan2, cfg, collect=True)
            jax.block_until_ready(out)
        # ONE offload for the whole chunk, at the boundary
        rec = Recorder()
        with recording(recorder=rec):
            dms = obsm.offload(out[3], rounds=plan2.rounds)
    assert rec.counters["host_offloads"] == 1
    assert len(dms) == S
    # boundary-offloaded metrics still match the wire exactly
    per_round = eng.emit_fused_payloads(out[1], out[2], plan2)
    for dm, (payloads, _) in zip(dms, per_round):
        assert dm["sparse_bytes"] == sum(p.nbytes for p in payloads)
        assert dm["codec_bytes"] == wire.codec_breakdown(payloads)
        assert dm["participants"] == K


# ---------------------------------------------------------------------------
# driver-level: telemetry-on parity, records, run telemetry
# ---------------------------------------------------------------------------

def test_telemetry_does_not_change_fused_trajectory(cohort):
    """The headline invariant: turning the flight recorder on changes
    no training bit — params, bytes, ε all identical."""
    plain = run_federated(cohort, _tcfg(3, loops=5), method="scbf",
                          mlp_features=FEATS)
    cfg = dataclasses.replace(_tcfg(3, loops=5),
                              obs=ObsConfig(device_metrics=True))
    with_obs = run_federated(cohort, cfg, method="scbf",
                             mlp_features=FEATS)
    assert _params_bitwise_equal(plain.final_params, with_obs.final_params)
    for ra, rb in zip(plain.records, with_obs.records):
        assert ra.sparse_bytes == rb.sparse_bytes
        assert ra.upload_fraction == rb.upload_fraction
        assert ra.epsilon == rb.epsilon
        assert ra.train_loss is None          # collect was off
        assert rb.train_loss is not None and rb.train_loss > 0


def test_fused_wall_is_amortized_flag(cohort):
    fused = run_federated(cohort, _tcfg(3, loops=6), method="scbf",
                          mlp_features=FEATS)
    per_round = run_federated(cohort, _tcfg(1, loops=3), method="scbf",
                              mlp_features=FEATS)
    assert all(r.wall_is_amortized for r in fused.records)
    assert not any(r.wall_is_amortized for r in per_round.records)
    # within one chunk every round reports the same chunk-wall/S share
    walls = [r.wall_time for r in fused.records]
    assert walls[0] == walls[1] == walls[2]
    assert all(w > 0 for w in walls)


def test_fused_loss_matches_per_round_loss(cohort):
    obs = ObsConfig(device_metrics=True)
    a = run_federated(cohort, _tcfg(1, loops=4, obs=obs), method="scbf",
                      mlp_features=FEATS)
    b = run_federated(cohort, _tcfg(2, loops=4, obs=obs), method="scbf",
                      mlp_features=FEATS)
    for ra, rb in zip(a.records, b.records):
        assert ra.train_loss == pytest.approx(rb.train_loss, rel=1e-6)


def test_fedavg_collect_round_loss(cohort):
    obs = ObsConfig(device_metrics=True)
    res = run_federated(cohort, _tcfg(2, loops=4, obs=obs),
                        method="fedavg", mlp_features=FEATS)
    assert all(r.train_loss is not None and r.train_loss > 0
               for r in res.records)


# ---------------------------------------------------------------------------
# the events.jsonl golden schema + run telemetry watchdogs
# ---------------------------------------------------------------------------

# Required fields per event kind — the schema-1 contract
# docs/OBSERVABILITY.md documents.  Extending an event with NEW fields
# is fine; removing/renaming one of these requires an EVENT_SCHEMA bump.
REQUIRED_FIELDS = {
    "meta": {"schema", "emitter"},
    "run_start": {"method", "loops", "clients", "engine", "fuse_rounds",
                  "mode"},
    "round": {"loop", "participants", "upload_fraction", "sparse_bytes",
              "dense_bytes", "wall", "wall_is_amortized", "hidden",
              "evaluated", "sampled", "dropped", "stragglers",
              "staleness_mean", "staleness_max", "train_loss",
              "selected", "codec_bytes"},
    "span": {"name", "dur"},
    "run_end": set(),
}


@pytest.fixture(scope="module")
def golden_run(cohort, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("obs") / "events.jsonl")
    with recording(path):
        res = run_federated(cohort, _tcfg(2, loops=4), method="scbf",
                            mlp_features=FEATS)
    return path, res


def test_events_jsonl_golden_schema(golden_run):
    path, _ = golden_run
    events = obs_report.read_events(path)
    assert events[0]["ev"] == "meta"
    kinds = [e["ev"] for e in events]
    assert kinds.count("run_start") == 1 and kinds.count("run_end") == 1
    assert kinds.count("round") == 4
    assert kinds.index("run_start") < kinds.index("round")
    for e in events:
        missing = REQUIRED_FIELDS.get(e["ev"], set()) - set(e)
        assert not missing, f"{e['ev']} event missing {missing}"
    spans = {e["name"] for e in events if e["ev"] == "span"}
    assert {"fused_chunk", "encode", "eval"} <= spans


def test_run_telemetry_watchdogs(golden_run):
    _, res = golden_run
    tel = res.telemetry
    assert tel is not None
    assert tel["fused_compiles"] <= 2          # the PR-4/5 bar holds
    assert tel["host_offloads"] == 2           # one per chunk (4 loops / 2)
    assert tel["events"] > 0 and tel["spans"] > 0


def test_recording_off_leaves_no_telemetry(cohort):
    res = run_federated(cohort, _tcfg(2, loops=2), method="scbf",
                        mlp_features=FEATS)
    assert res.telemetry is None


# ---------------------------------------------------------------------------
# the report pipeline
# ---------------------------------------------------------------------------

def test_report_cli_end_to_end(golden_run, tmp_path, capsys):
    path, res = golden_run
    json_out = str(tmp_path / "report.json")
    trace_out = str(tmp_path / "trace.json")
    assert obs_report.main([path, "--json-out", json_out,
                            "--trace-out", trace_out]) == 0
    table = capsys.readouterr().out
    assert "loop" in table and "~" in table    # amortized marker shown
    summary = json.load(open(json_out))
    assert summary["schema"] == EVENT_SCHEMA
    assert summary["rounds"] == 4
    assert summary["total_sparse_bytes"] == \
        sum(r.sparse_bytes for r in res.records)
    assert summary["final_train_loss"] == res.records[-1].train_loss
    assert summary["wall_is_amortized"] is True
    assert summary["compiles"]["fused_compiles"] <= 2
    trace = json.load(open(trace_out))
    assert any(e["ph"] == "X" for e in trace["traceEvents"])


def test_report_refuses_schema_mismatch(tmp_path, capsys):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"ev": "meta", "ts": 0.0, "schema": 99,
                               "emitter": "repro.obs/99"}) + "\n")
    with pytest.raises(ValueError, match="schema 99"):
        obs_report.read_events(str(bad))
    assert obs_report.main([str(bad)]) == 1
    assert "schema" in capsys.readouterr().err


def test_report_refuses_non_event_file(tmp_path):
    f = tmp_path / "x.jsonl"
    f.write_text('{"ev": "round"}\n')
    with pytest.raises(ValueError, match="meta"):
        obs_report.read_events(str(f))
