"""Channel-norm algebra: separability, quantiles, exact edge masks.

The central invariant (paper §2.1 + DESIGN.md §3): an edge is uploaded
iff it lies on at least one channel whose norm clears the threshold.  We
check the fast mask against brute-force channel enumeration.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# degrades to per-test skips when hypothesis is missing, instead of a
# module-level collection error
from _hypothesis_compat import given, settings, st

from repro.core import channels
from repro.models.mlp_net import init_mlp


def random_grads(sizes, seed=0):
    rng = np.random.default_rng(seed)
    gs = []
    for fin, fout in zip(sizes[:-1], sizes[1:]):
        gs.append({"w": jnp.asarray(rng.normal(size=(fin, fout)),
                                    jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(fout,)), jnp.float32)})
    return gs


def test_separability():
    gs = random_grads((5, 4, 3, 2))
    scores = channels.layer_scores(gs)
    T = channels.materialize_channel_tensor(scores)
    assert T.shape == (4, 3, 2)
    # brute force: T[i,j,k] = s1[i]+s2[j]+s3[k]
    for i, j, k in itertools.product(range(4), range(3), range(2)):
        want = float(scores[0][i] + scores[1][j] + scores[2][k])
        assert float(T[i, j, k]) == pytest.approx(want, rel=1e-6)


def test_layer_scores_definition():
    gs = random_grads((6, 3, 1))
    s = channels.layer_scores(gs)
    w, b = np.asarray(gs[0]["w"]), np.asarray(gs[0]["b"])
    want = (w ** 2).sum(0) + b ** 2
    np.testing.assert_allclose(np.asarray(s[0]), want, rtol=1e-6)


def test_quantile_exact_small():
    gs = random_grads((5, 4, 3, 2))
    scores = channels.layer_scores(gs)
    thr = channels.channel_quantile(scores, 0.25, selection="positive")
    T = channels.materialize_channel_tensor(scores).reshape(-1)
    frac_above = float(jnp.mean(T > thr))
    assert frac_above == pytest.approx(0.25, abs=2 / T.shape[0])


def test_quantile_sampled_close_to_exact(monkeypatch):
    gs = random_grads((10, 16, 16, 8), seed=3)
    scores = channels.layer_scores(gs)
    exact = channels.channel_quantile(scores, 0.1)
    monkeypatch.setattr(channels, "MAX_MATERIALIZED", 10)
    approx = channels.channel_quantile(scores, 0.1,
                                       key=jax.random.PRNGKey(0),
                                       num_samples=1 << 15)
    assert float(approx) == pytest.approx(float(exact), rel=0.05)


def brute_force_edge_mask(scores, thr):
    """Edge (p,q,l) uploaded iff ∃ channel through it with norm > thr."""
    sizes = [int(s.shape[0]) for s in scores]
    L = len(sizes)
    masks = [np.zeros((1 if l == 0 else sizes[l - 1], sizes[l]), bool)
             for l in range(L)]
    bmasks = [np.zeros(sizes[l], bool) for l in range(L)]
    for ch in itertools.product(*[range(n) for n in sizes]):
        norm = sum(float(scores[l][ch[l]]) for l in range(L))
        if norm > thr:
            for l in range(L):
                if l == 0:
                    masks[0][0, ch[0]] = True
                else:
                    masks[l][ch[l - 1], ch[l]] = True
                bmasks[l][ch[l]] = True
    return masks, bmasks


@pytest.mark.parametrize("alpha", [0.05, 0.25, 0.6])
def test_edge_mask_exactness(alpha):
    gs = random_grads((7, 5, 4, 3), seed=42)
    scores = channels.layer_scores(gs)
    thr = channels.channel_quantile(scores, alpha)
    masked, masks = channels.apply_channel_mask(gs, scores, thr)
    bf_masks, bf_bias = brute_force_edge_mask(
        [np.asarray(s) for s in scores], float(thr))
    # layer 0: every input edge of a selected layer-1 neuron
    got0 = np.asarray(masks[0]["w"])[0]          # rows identical (broadcast)
    np.testing.assert_array_equal(got0, bf_masks[0][0])
    for l in range(1, 3):
        np.testing.assert_array_equal(np.asarray(masks[l]["w"]),
                                      bf_masks[l])
        np.testing.assert_array_equal(np.asarray(masks[l]["b"]), bf_bias[l])
    # masked gradients: zeros exactly off-mask
    for l, (g, m) in enumerate(zip(gs, masks)):
        w = np.asarray(masked[l]["w"])
        assert np.all((w != 0) <= np.asarray(m["w"]))
        np.testing.assert_array_equal(
            w[np.asarray(m["w"])], np.asarray(g["w"])[np.asarray(m["w"])])


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(2, 5), st.integers(1, 3),
       st.floats(0.05, 0.9), st.integers(0, 10_000))
def test_mask_monotone_in_threshold(m1, m2, m3, alpha, seed):
    gs = random_grads((4, m1, m2, m3), seed=seed)
    scores = channels.layer_scores(gs)
    thr_lo = channels.channel_quantile(scores, min(alpha + 0.1, 0.95))
    thr_hi = channels.channel_quantile(scores, alpha)
    _, masks_lo = channels.apply_channel_mask(gs, scores, thr_lo)
    _, masks_hi = channels.apply_channel_mask(gs, scores, thr_hi)
    # a higher threshold (smaller upload) selects a subset of edges
    for ml, mh in zip(masks_hi, masks_lo):
        assert np.all(np.asarray(ml["w"]) <= np.asarray(mh["w"]))


def test_factored_threshold_no_matrix_leaves():
    """A pytree with no >=2-D leaves must not crash on an empty
    concatenate — everything uploads (threshold -inf)."""
    grads = {"scale": jnp.ones((5,)), "bias": jnp.zeros((3,))}
    _, scores = channels.factored_scores(grads)
    thr = channels.factored_threshold(scores, 0.25)
    assert float(thr) == -np.inf
    masked, frac = channels.apply_factored_mask(grads, 0.25)
    assert float(frac) == pytest.approx(1.0)
    for a, b in zip(jax.tree_util.tree_leaves(grads),
                    jax.tree_util.tree_leaves(masked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_factored_mask_tied_scores_keep_channels():
    """When every channel score ties at the threshold (e.g. uniform
    gradients), the mask must keep the tied channels rather than drop
    all of them — an upload_rate > 0 never uploads nothing."""
    grads = {"w": jnp.ones((4, 8), jnp.float32)}
    masked, frac = channels.apply_factored_mask(grads, 0.5)
    assert float(frac) > 0.0
    assert float(jnp.sum(jnp.abs(masked["w"]))) > 0.0


def test_channel_mask_biasless_layer_has_none_bias_mask():
    """Layers without a bias transmit no bias tensor, so their mask's
    "b" entry is None and the upload accounting skips it."""
    from repro.core import selection
    gs = random_grads((6, 4, 2))
    gs[1] = {"w": gs[1]["w"]}                       # strip the bias
    scores = channels.layer_scores(gs)
    thr = channels.channel_quantile(scores, 0.25)
    masked, masks = channels.apply_channel_mask(gs, scores, thr)
    assert masks[1]["b"] is None
    assert "b" not in masked[1]
    st_ = selection.UploadStats.from_masks(masks)
    assert st_.total_params == sum(
        int(np.prod(g["w"].shape)) for g in gs) + gs[0]["b"].shape[0]
    assert st_.sparse_bytes <= st_.dense_bytes


def test_factored_mask_fraction():
    params = init_mlp((64, 32, 16, 1), jax.random.PRNGKey(0))
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape), params)
    masked, frac = channels.apply_factored_mask(grads, 0.2)
    # kept fraction should be near-ish the rate (1-D leaves always kept)
    assert 0.1 < float(frac) < 0.5
    # idempotence: masking the masked grads keeps them unchanged
    masked2, _ = channels.apply_factored_mask(masked, 0.9999)
    for a, b in zip(jax.tree_util.tree_leaves(masked),
                    jax.tree_util.tree_leaves(masked2)):
        zero_a = np.asarray(a) == 0
        np.testing.assert_array_equal(np.asarray(b)[zero_a], 0)
