"""BAD (SL005): a rank-2 padded slot table broadcasts against a rank-1
clean per-channel array — every channel column inherits the dead slots
silently, and nothing downstream knows the result is padded."""
import jax.numpy as jnp


def _pad_slots(x, b):
    """Producer stub with the PR 3 padder's name and contract."""
    return x


def widen_padding(b, k):
    padded = _pad_slots(jnp.zeros((b, k)), b)   # (B, K), B has dead slots
    channel_scale = jnp.ones((k,))              # (K,), clean
    return padded * channel_scale               # SL005: rank 2 vs rank 1
