"""BAD (SL001, interprocedural): the padded array is reduced by a
helper in ANOTHER module; the finding must land inside
``reduce_helper.total`` — padding provenance crossed the module
boundary via the call-site → parameter propagation."""
import jax.numpy as jnp

from bad.reduce_helper import total


def _pad_slots(x, b):
    """Producer stub with the PR 3 padder's name and contract."""
    return x


def loss_via_helper(losses, b):
    padded = _pad_slots(losses, b)
    return total(padded)
