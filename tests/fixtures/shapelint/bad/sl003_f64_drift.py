"""BAD (SL003): float64 drift inside jit-reachable code — an f64
scalar minted with ``np.float64`` and an ``astype(float)`` (numpy:
float64) both silently change the compute dtype under jit."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def drifting_step(params, grads):
    scale = np.float64(0.5)             # SL003: f64 creation in trace
    wide = grads.astype(float)          # SL003: astype(float) is f64
    return params - scale * wide
