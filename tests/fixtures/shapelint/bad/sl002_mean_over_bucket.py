"""BAD (SL002): the "mean over B instead of Σvalid" bug, in both of
its shapes, against the verbatim PR 9 admit-mask layout — the numerator
is correctly validity-masked, but the denominator counts every bucket
slot including the dead ones."""
import jax.numpy as jnp


def bucket_size(p_count, num_clients):
    """Bucket capacity ≥ p_count (the PR 3 producer shape)."""
    b = 1
    while b < p_count:
        b *= 2
    return min(b, num_clients)


def mean_over_bucket(losses, admit):
    masked = jnp.where(admit, losses, 0.0)
    return jnp.mean(masked)             # SL002: divides by B, not Σadmit


def sum_over_capacity(losses, admit, p_count, num_clients):
    b = bucket_size(p_count, num_clients)
    masked_sum = jnp.sum(jnp.where(admit, losses, 0.0))
    return masked_sum / b               # SL002: b counts dead slots
