"""Helper module for the interprocedural fixture: ``total`` is CLEAN
in isolation (summing an arbitrary array is fine) — it only becomes an
SL001 once a caller in another module feeds it a padded array."""
import jax.numpy as jnp


def total(xs):
    return jnp.sum(xs)
