"""BAD (SL004): a boolean validity mask used arithmetically without an
explicit cast — ``jnp.sum(valid)`` and ``x * valid`` both rely on the
implicit, dtype-dependent bool→int promotion."""
import jax.numpy as jnp


def participant_tally(valid):
    return jnp.sum(valid)               # SL004: bool sum, no cast


def masked_by_promotion(per_slot, valid):
    return per_slot * valid             # SL004: bool arithmetic
