"""BAD (SL006): dividing by (and taking the log of) Σvalid with no
positive guard — the all-slots-masked round (quorum miss, total fault
injection) makes the denominator 0 and poisons the aggregate with
inf/nan."""
import jax.numpy as jnp


def unguarded_mean(loss_sum, valid):
    n = jnp.sum(valid.astype(jnp.float32))
    return loss_sum / n                 # SL006: n == 0 when all masked


def unguarded_log(valid):
    n = jnp.sum(valid.astype(jnp.float32))
    return jnp.log(n)                   # SL006: log(0) = -inf
