"""BAD (SL001): the verbatim PR 3 bucketed-padding shape — a cohort of
``p_count`` losses is repeat-padded up to the bucket capacity ``b``,
then reduced WITHOUT a validity mask.  The tail slots hold copies of
slot 0, so the sum double-counts slot 0 ``b - p_count`` times."""
import jax.numpy as jnp


def _pad_slots(x, b):
    """Repeat-fill the tail slots with slot 0 (the PR 3 idiom)."""
    pad = jnp.tile(x[:1], (b - x.shape[0],))
    return jnp.concatenate([x, pad])


def round_loss_sum(losses, b):
    padded = _pad_slots(losses, b)
    return jnp.sum(padded)              # SL001: no mask, no slice
