"""GOOD: deliberate float64 on the HOST side — privacy accounting and
wall-clock bookkeeping live outside any trace, where f64 is the right
call (RDP epsilons lose precision in f32).  SL003 is scoped to
jit-reachable code, so this file has zero findings."""
import numpy as np


def epsilon_ledger(sigmas, q):
    # f64 accumulation on host: exempt from SL003 (not in a trace)
    total = np.float64(0.0)
    for s in sigmas:
        total += np.float64(q) / np.float64(s) ** 2
    return float(total)


def wall_clock_stats(durations):
    arr = np.asarray(durations, dtype=np.float64)
    return float(arr.mean()), float(arr.max())
