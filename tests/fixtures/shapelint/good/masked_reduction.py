"""GOOD: every sanctioned padding-discipline idiom the repo uses —
validity-masked sums with guarded Σvalid denominators, slicing back to
the live prefix, explicit mask casts, and the masked-quantile pattern.
Zero findings."""
import jax.numpy as jnp


def _pad_slots(x, b):
    """Producer stub with the PR 3 padder's name and contract."""
    return x


def masked_mean(losses, valid, b):
    padded = _pad_slots(losses, b)
    # the canonical fused-path accounting: masked sum over Σvalid,
    # with a positive guard for the all-masked round
    loss_sum = jnp.sum(jnp.where(valid, padded, 0.0))
    n = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
    return loss_sum / n


def sliced_sum(losses, b, p_count):
    padded = _pad_slots(losses, b)
    # the sequential-path idiom: slice back to the live prefix
    return jnp.sum(padded[:p_count])


def cast_tally(valid):
    # explicit cast before arithmetic on a boolean mask
    return jnp.sum(valid.astype(jnp.int32))


def masked_weighting(per, weights):
    # float weights are exact zeros at dead slots: multiplication
    # clears the padding, the guard clears the zero denominator
    num = jnp.sum(per * weights)
    den = jnp.maximum(jnp.sum(weights), 1.0)
    return num / den
