"""pallas_call contract breaches tracelint can prove statically: an
index map whose arity disagrees with the grid rank, and one returning
the wrong number of block coordinates.  Both compile to garbage
indexing instead of failing at the call site (TL005)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_kernel(x_ref, o_ref):
    o_ref[...] = (x_ref[...] == 0.0).sum(axis=0)


def bad_arity_counts(x, bb: int = 8, bn: int = 128):
    b, n = x.shape
    return pl.pallas_call(
        _count_kernel,
        grid=(b // bb, n // bn),
        in_specs=[pl.BlockSpec((bb, bn), lambda i: (i, 0))],   # 2D grid
        out_specs=pl.BlockSpec((bn,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
    )(x)


def bad_rank_counts(x, bb: int = 8, bn: int = 128):
    b, n = x.shape
    return pl.pallas_call(
        _count_kernel,
        grid=(b // bb, n // bn),
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i,))],  # 2D block
        out_specs=pl.BlockSpec((bn,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
    )(x)
