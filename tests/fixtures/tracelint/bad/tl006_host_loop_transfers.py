"""Per-iteration device→host crossings in host loops — the reason the
fused round loop exists.  tracelint must flag device_get, np.asarray of
a jitted call, and block_until_ready inside the loop body (TL006)."""
import jax
import jax.numpy as jnp
import numpy as np

train_round = jax.jit(lambda p, b: p + b.mean())


def run_rounds(params, batches):
    history = []
    for b in batches:
        params = train_round(params, b)
        history.append(jax.device_get(params))          # sync per round
        history.append(np.asarray(train_round(params, b)))
        params.block_until_ready()                      # serializes dispatch
    return params, history
