"""The PR 3 recompile class: a jitted function fed a slice whose
extent changes per loop iteration.  jit caches per shape, so every
distinct extent is a fresh compile.  tracelint must flag the call site
(TL004) both for a direct slice argument and for a local assigned from
one."""
import jax
import jax.numpy as jnp

score_batch = jax.jit(lambda x: jnp.tanh(x).sum(axis=1))


def stream_scores(x, sizes):
    out = []
    start = 0
    for n in sizes:                        # n varies per iteration
        out.append(score_batch(x[start:start + n]))     # direct slice
        xb = x[start:start + n]
        out.append(score_batch(xb))                     # via a local
        start += n
    return out
