"""Host syncs on traced values (the PR 4 lr-bug class): ``.item()``
and ``float()`` inside a jitted function, and ``float()`` directly on a
jitted call in the host tier.  tracelint must flag each (TL002)."""
import jax
import jax.numpy as jnp


@jax.jit
def scaled_update(params, grads, lr):
    scale = float(lr)                       # device→host sync under trace
    return jax.tree_util.tree_map(
        lambda p, g: p - scale * g, params, grads)


@jax.jit
def loss_scalar(logits, targets):
    loss = jnp.mean((logits - targets) ** 2)
    return loss.item()                      # fails under jit outright


_forward = jax.jit(lambda p, x: x @ p)


def host_metric(p, x):
    return float(_forward(p, x))            # blocks dispatch per call
