"""Python control flow on tracer values inside traced functions:
either a ConcretizationTypeError at trace time, or one branch silently
baked into the compiled program.  tracelint must flag both the ``if``
and the ``while`` (TL003)."""
import jax
import jax.numpy as jnp


@jax.jit
def clamp_step(delta, threshold):
    if delta.sum() > threshold:             # branches on a tracer
        return delta * 0.5
    return delta


@jax.jit
def iterate(x):
    while x.max() > 1.0:                    # tracer-valued loop condition
        x = x * 0.9
    return x
