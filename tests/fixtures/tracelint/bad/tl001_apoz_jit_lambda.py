"""Verbatim reduction of the PR 5 bug: ``apoz_scores`` built a
``jax.jit(lambda ...)`` inside the pruning step, so every prune loop
recompiled the APoZ scorer.  tracelint must flag the per-call jit on a
lambda (TL001) — the fix is the module-level jitted
``repro.kernels.apoz.apoz_batch_fractions``."""
import jax
import jax.numpy as jnp


def _hidden_acts(params, x):
    acts = []
    for layer in params[:-1]:
        x = jnp.maximum(x @ layer["w"] + layer["b"], 0.0)
        acts.append(x)
    return acts


def apoz_scores(params, x_val, batch_size: int = 2048):
    scorer = jax.jit(lambda p, xb: [jnp.mean(a == 0.0, axis=0)
                                    for a in _hidden_acts(p, xb)])
    totals = None
    for start in range(0, x_val.shape[0], batch_size):
        frac = scorer(tuple(params), x_val[start:start + batch_size])
        totals = frac if totals is None else [
            t + f for t, f in zip(totals, frac)]
    return totals
