"""Verbatim reduction of the PR 1 bug: ``scbf._evaluate`` wrapped
``jax.jit(mlp_forward)`` inside the function body, so every evaluation
built a fresh wrapper with a fresh compilation cache and retraced the
forward pass from scratch.  tracelint must flag the jit construction
(TL001) — the fix hoisted it to a module-level ``_mlp_forward_jit``."""
import jax
import jax.numpy as jnp
import numpy as np


def mlp_forward(params, x, neuron_masks=None):
    for layer in params:
        x = jnp.maximum(x @ layer["w"] + layer["b"], 0.0)
    return x[:, 0]


def _evaluate(params, x, y, batch: int = 8192, neuron_masks=None):
    forward = jax.jit(mlp_forward)      # rebuilt (and re-traced) per call
    scores = []
    for s in range(0, x.shape[0], batch):
        scores.append(np.asarray(forward(
            tuple(params), jnp.asarray(x[s:s + batch]), neuron_masks)))
    return np.concatenate(scores)
