"""Host-loop patterns that are the RECOMMENDED fixes — tracelint must
report nothing: device-resident accumulation with one post-loop
gather, comprehension gathers, and fixed-shape streaming through a
module-level jit."""
import jax
import jax.numpy as jnp
import numpy as np

train_round = jax.jit(lambda p, b: p + b.mean())
score_batch = jax.jit(lambda x: jnp.tanh(x).sum(axis=1))

BATCH = 256


def run_rounds(params, batches):
    # accumulate on device; transfer ONCE after the loop
    history = []
    for b in batches:
        params = train_round(params, b)
        history.append(params)
    return params, [np.asarray(h) for h in history]


def stream_fixed(x):
    # fixed extent per iteration: one compile for the whole stream
    out = []
    for start in range(0, x.shape[0] - BATCH + 1, BATCH):
        out.append(score_batch(x[start:start + BATCH]))
    return jnp.concatenate(out)
