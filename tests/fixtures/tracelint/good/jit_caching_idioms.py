"""Every accepted jit-caching idiom in one file — tracelint must
report NOTHING here.  These mirror the real fixes: the module-level
wrapper (PR 1), the ``lru_cache`` factory (``fed.engine``), the
cache-guarded attribute (engine lazy-build), and jit-as-decorator."""
import functools

import jax
import jax.numpy as jnp


def mlp_forward(params, x):
    for layer in params:
        x = jnp.maximum(x @ layer["w"] + layer["b"], 0.0)
    return x


# idiom 1: module-level wrapper — one compile cache for the process
_mlp_forward_jit = jax.jit(mlp_forward)

# idiom 1b: partial-applied jit with static args, still module level
local_train = functools.partial(jax.jit, static_argnames=("epochs",))(
    mlp_forward)


# idiom 2: lru_cache factory — one wrapper per config signature
@functools.lru_cache(maxsize=None)
def _fused_programs(horizon: int, num_slots: int):
    def chunk(params, plan):
        return jax.lax.scan(lambda p, r: (p, None), params, plan)
    return jax.jit(chunk)


# idiom 3: cache-guarded attribute — lazy build, reused thereafter
class Engine:
    def __init__(self):
        self._step = None

    def step(self, params, batch):
        if self._step is None:
            self._step = jax.jit(mlp_forward)
        return self._step(params, batch)


# idiom 3b: dict-slot cache with a membership guard
_PROGRAMS = {}


def program_for(key: str):
    if key not in _PROGRAMS:
        _PROGRAMS[key] = jax.jit(mlp_forward)
    return _PROGRAMS[key]


# idiom 4: jit as a decorator on a module-level def
@jax.jit
def scbf_sum_step(params, deltas):
    return jax.tree_util.tree_map(lambda p, d: p + d, params, deltas)


@functools.partial(jax.jit, static_argnames=("upload_rate",))
def masked_sum(params, deltas, upload_rate: float = 0.1):
    return jax.tree_util.tree_map(lambda p, d: p + d * upload_rate,
                                  params, deltas)
