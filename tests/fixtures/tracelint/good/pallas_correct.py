"""A contract-correct pallas_call mirroring ``kernels.apoz`` — 2-axis
grid, index maps with matching arity and block-rank coordinates.
tracelint must report nothing (TL005 false positives here would poison
every kernel in the repo)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_kernel(x_ref, o_ref):
    o_ref[...] = (x_ref[...] == 0.0).sum(axis=0).astype(jnp.int32)


def apoz_counts(x, bb: int = 8, bn: int = 128):
    b, n = x.shape
    grid = (b // bb, n // bn)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bn,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
    )(x)
