"""Branching that LOOKS tracer-dependent but is static — tracelint
must report nothing.  Mirrors the real sites: shape attributes,
dict-pytree membership, config fields, ``is None`` tests, and a
shape-only helper (``channels.num_channels``)."""
import jax
import jax.numpy as jnp

MAX_MATERIALIZED = 1 << 22


def num_channels(scores):
    n = 1
    for s in scores:
        n *= int(s.shape[0])
    return n


@jax.jit
def apply_bias(p, x):
    if "bias" in p:                      # dict membership: structural
        x = x + p["bias"]
    if x.ndim == 3:                      # shape attribute: static
        x = x.reshape(x.shape[0], -1)
    return x


@jax.jit
def select(scores, threshold, *, exact: bool = True):
    if num_channels(scores) <= MAX_MATERIALIZED:   # shape-only helper
        pass
    if exact:                            # keyword-only: static config
        return [jnp.where(s >= threshold, s, 0.0) for s in scores]
    return scores


@jax.jit
def maybe_mask(x, mask=None):
    if mask is None:                     # identity test: python-level
        return x
    return x * mask


def layer_specs(cfg, x):
    # attribute access on a config param is a field read, not a
    # tracer concretization
    if cfg.encoder_layers:
        return ["cross"] * int(cfg.encoder_layers)
    if bool(cfg.cross_attn_every):
        return ["cross", "self"]
    return ["self"] * x.ndim
