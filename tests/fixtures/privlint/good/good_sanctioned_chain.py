"""GOOD: the full sanctioned chain — selection → per-participant noise
keys → release-ledger accounting → wire encode.  Zero findings."""
import jax

from repro.comm import wire
from repro.core import privacy
from repro.fed.engine import client_delta, local_train
from repro.fed.selection import select_gradients


def federated_round(params, shards, lr, key, rate, sigma, clip,
                    dp_releases=0):
    payloads = []
    for x, y in shards:
        key, kc, ks, kd = jax.random.split(key, 4)
        new_p = local_train(tuple(params), x, y, lr, kc)
        delta = client_delta(tuple(params), new_p)
        masked, masks, _ = select_gradients(delta, rate, "magnitude",
                                            key=ks)
        noised = privacy.gaussian_mechanism(tuple(masked), kd, sigma,
                                            clip, masks=masks)
        dp_releases += 1
        payloads.append(wire.encode(tuple(noised)))
    eps = privacy.epsilon_for(sigma, 1e-5, loops=dp_releases)
    return payloads, eps
