"""GOOD: mask-mode compacted geometry — the reveal set is only ever
NARROWED after noising (validity zeroing, logical_and, kept-row
slicing), which reveals strictly less than the noise was calibrated
for.  Zero findings."""
import jax
import jax.numpy as jnp

from repro.comm import wire
from repro.core import privacy
from repro.fed.selection import select_gradients


def emit_compacted(delta, keep, valid, rate, sigma, clip, key,
                   dp_releases=0):
    ks, kd = jax.random.split(key)
    masked, masks, _ = select_gradients(delta, rate, "magnitude",
                                        key=ks)
    noised = privacy.gaussian_mechanism(tuple(masked), kd, sigma, clip,
                                        masks=masks)
    # narrowing is allowed: zero invalid slots, intersect with the
    # validity mask, then slice down to the kept (compacted) rows
    noised = [jnp.where(valid, g, 0.0) for g in noised]
    masks = [jnp.logical_and(m, valid) for m in masks]
    kept = [g[k] for g, k in zip(noised, keep)]
    kept_masks = [m[k] for m, k in zip(masks, keep)]
    dp_releases += 1
    eps = privacy.epsilon_for(sigma, 1e-5, loops=dp_releases)
    return wire.encode(tuple(kept)), kept_masks, eps
