"""GOOD: telemetry receives only declared cohort-level aggregates, and
the DP-off selection-only upload (the paper's base SCBF) is allowed on
the wire — it makes no (ε, δ) claim.  Zero findings."""
from repro.comm import wire
from repro.fed.engine import client_delta, local_train
from repro.fed.selection import select_gradients
from repro.obs import metrics, trace


def selection_only_round(params, x, y, lr, key, rate, skey):
    new_p, loss = local_train(tuple(params), x, y, lr, key,
                              with_loss=True)
    delta = client_delta(tuple(params), new_p)
    masked, masks, _ = select_gradients(delta, rate, "magnitude",
                                        key=skey)
    dm = metrics.offload(loss)
    trace.event("round", train_loss=dm["train_loss"])
    return wire.encode(tuple(masked)), dm
