"""BAD (PL001, interprocedural): the dense delta is routed through a
helper in ANOTHER module; the finding must land inside
``leak_helper.ship_update`` — taint crossed the module boundary via
the call-site → parameter propagation."""
from bad.leak_helper import ship_update
from repro.fed.engine import client_delta, local_train


def upload_via_helper(params, x, y, lr, key):
    new_p = local_train(tuple(params), x, y, lr, key)
    delta = client_delta(tuple(params), new_p)
    return ship_update(delta)
