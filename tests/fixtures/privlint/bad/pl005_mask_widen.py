"""BAD (PL005): the reveal mask is widened AFTER the Gaussian noise was
calibrated to it — the extra coordinates ship with zero noise budget.
Includes the mask-mode compacted-geometry variant (re-appending rows
with np.concatenate)."""
import jax.numpy as jnp
import numpy as np

from repro.comm import wire
from repro.core import privacy
from repro.fed.selection import select_gradients


def widen_after_noise(grads, extra_masks, rate, sigma, clip, skey,
                      dkey, dp_releases=0):
    masked, masks, _ = select_gradients(grads, rate, "magnitude",
                                        key=skey)
    noised = privacy.gaussian_mechanism(tuple(masked), dkey, sigma,
                                        clip, masks=masks)
    masks = jnp.logical_or(masks, extra_masks)
    dp_releases += 1
    return wire.encode(tuple(noised)), masks


def widen_compacted_geometry(grads, keep_rows, rate, sigma, clip, skey,
                             dkey, dp_releases=0):
    masked, masks, _ = select_gradients(grads, rate, "magnitude",
                                        key=skey)
    noised = privacy.gaussian_mechanism(tuple(masked), dkey, sigma,
                                        clip, masks=masks)
    # compacted keep-mask geometry grown back after noising
    masks = np.concatenate([masks, keep_rows], axis=0)
    dp_releases += 1
    return wire.encode(tuple(noised)), masks
