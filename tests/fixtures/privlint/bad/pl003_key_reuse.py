"""BAD (PL003): PRNG key hygiene violations on the noise path — a
loop-invariant key (every client draws the same noise) and one key
consumed by two releases."""
import jax

from repro.comm import wire
from repro.core import privacy
from repro.fed.selection import select_gradients


def run_rounds(grads_by_client, rate, sigma, clip, seed,
               dp_releases=0):
    key = jax.random.PRNGKey(seed)
    out = []
    for g in grads_by_client:
        masked, masks, _ = select_gradients(g, rate, "magnitude",
                                            key=key)
        # `key` is never re-split inside the loop
        noised = privacy.gaussian_mechanism(tuple(masked), key, sigma,
                                            clip, masks=masks)
        dp_releases += 1
        out.append(wire.encode(tuple(noised)))
    eps = privacy.epsilon_for(sigma, 1e-5, loops=dp_releases)
    return out, eps


def double_release(masked_a, masked_b, sigma, clip, key):
    na = privacy.gaussian_mechanism(tuple(masked_a), key, sigma, clip)
    nb = privacy.gaussian_mechanism(tuple(masked_b), key, sigma, clip)
    return na, nb
