"""BAD (PL004): accounting skew — a noised payload emitted with no
accountant update anywhere on the call chain, and a ledger that spends
the budget twice for one emission."""
import jax

from repro.comm import wire
from repro.core import privacy
from repro.fed.selection import select_gradients


def emit_unaccounted(grads, rate, sigma, clip, key):
    k1, k2 = jax.random.split(key)
    masked, masks, _ = select_gradients(grads, rate, "magnitude",
                                        key=k1)
    noised = privacy.gaussian_mechanism(tuple(masked), k2, sigma, clip,
                                        masks=masks)
    return wire.encode(tuple(noised))


def emit_double_counted(grads, rate, sigma, clip, key, dp_releases):
    k1, k2 = jax.random.split(key)
    masked, masks, _ = select_gradients(grads, rate, "magnitude",
                                        key=k1)
    noised = privacy.gaussian_mechanism(tuple(masked), k2, sigma, clip,
                                        masks=masks)
    dp_releases += 1
    payload = wire.encode(tuple(noised))
    dp_releases += 1
    return payload
