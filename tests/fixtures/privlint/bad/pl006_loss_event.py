"""BAD (PL006): a per-client training loss written straight to the
event log — events.jsonl is outside the privacy boundary."""
from repro.fed.engine import local_train
from repro.obs import trace


def train_and_log(params, x, y, lr, key):
    new_p, loss = local_train(tuple(params), x, y, lr, key,
                              with_loss=True)
    trace.event("client_done", loss=loss)
    return new_p
