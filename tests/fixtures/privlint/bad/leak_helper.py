"""Helper module for the interprocedural pair: encodes whatever it is
handed.  Harmless alone — the leak only exists at call sites that hand
it un-sanitized values (see pl001_interproc.py)."""
from repro.comm import wire


def ship_update(update):
    return wire.encode(tuple(update))
