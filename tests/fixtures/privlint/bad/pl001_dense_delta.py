"""BAD (PL001): the dense client delta ships to the wire un-selected
and un-noised — the server would see the exact per-client update."""
from repro.comm import wire
from repro.fed.engine import client_delta, local_train


def upload_round(params, x, y, lr, key):
    new_p = local_train(tuple(params), x, y, lr, key)
    delta = client_delta(tuple(params), new_p)
    return wire.encode(tuple(delta))
