"""BAD (PL003): verbatim reduction of the worst real finding this rule
caught — the fused-plan key padding in
``repro/fed/engine.py BatchedEngine.prepare_fused_plan.pad_rows``
(fixed in the same PR that shipped privlint).  Every padded slot gets
slot 0's key row, so all padding slots share one noise stream."""
import jax
import numpy as np


def pad_rows(rows, horizon, num_slots, trailing=(2,)):
    out = np.zeros((horizon, num_slots) + trailing, np.uint32)
    for r, k in enumerate(rows):
        k = np.asarray(k)
        if k.shape[0]:
            out[r, :k.shape[0]] = k
            out[r, k.shape[0]:] = k[0]
    return out


def plan_keys(key, horizon, num_slots):
    rows = [jax.random.split(key, num_slots - 1)
            for _ in range(horizon)]
    return pad_rows(rows, horizon, num_slots)
