"""BAD (PL002): DP noise applied AFTER the payload was encoded — the
un-noised coordinates have already left the privacy boundary."""
from repro.comm import wire
from repro.core import privacy
from repro.fed.selection import select_gradients


def ship(grads, skey, dkey, rate, sigma, clip):
    masked, masks, _ = select_gradients(grads, rate, "magnitude",
                                        key=skey)
    payload = wire.encode(tuple(masked))
    noised = privacy.gaussian_mechanism(payload, dkey, sigma, clip)
    return noised
