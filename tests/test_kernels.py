"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(8, 8), (256, 256), (100, 300), (512, 64), (7, 9), (1024, 128),
          (33, 257)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_channel_norms_sweep(shape, dtype):
    g = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    row, col = ops.channel_norms(g)
    row_ref, col_ref = ref.channel_norms_ref(g)
    np.testing.assert_allclose(np.asarray(row), np.asarray(row_ref),
                               rtol=2e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(col), np.asarray(col_ref),
                               rtol=2e-3, atol=1e-5)
    assert row.dtype == jnp.float32 and col.dtype == jnp.float32


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_select_mask_sweep(shape, dtype, q):
    key = jax.random.PRNGKey(1)
    g = jax.random.normal(key, shape).astype(dtype)
    row, col = ref.channel_norms_ref(g)
    thr = jnp.quantile(row[:, None] + col[None, :], q)
    got = ops.select_mask(g, row, col, thr)
    want = ref.select_mask_ref(g, row, col, thr)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("shape", SHAPES)
def test_fused_count_sweep(shape):
    g = jax.random.normal(jax.random.PRNGKey(2), shape)
    row, col = ref.channel_norms_ref(g)
    thr = jnp.median(row[:, None] + col[None, :])
    masked, cnt = ops.scbf_select_fused(g, row, col, thr)
    want_mask, want_cnt = ref.scbf_select_fused_ref(g, row, col, thr)
    np.testing.assert_array_equal(np.asarray(masked), np.asarray(want_mask))
    assert int(cnt) == int(want_cnt)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_select_compact_sweep(shape, dtype, q):
    """Fused select-and-compact vs its jnp oracle: identical COO buffers
    (row-major order), identical counts."""
    g = jax.random.normal(jax.random.PRNGKey(7), shape).astype(dtype)
    row, col = ref.channel_norms_ref(g)
    thr = jnp.quantile(row[:, None] + col[None, :], q)
    idx, vals, cnt = ops.select_compact(g, row, col, thr)
    idx_ref, vals_ref, cnt_ref = ref.select_compact_ref(g, row, col, thr)
    assert int(cnt) == int(cnt_ref)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_ref))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals_ref))


def test_select_compact_capacity_truncates_in_order():
    g = jax.random.normal(jax.random.PRNGKey(8), (32, 16))
    row, col = ref.channel_norms_ref(g)
    thr = jnp.quantile(row[:, None] + col[None, :], 0.5)
    full_idx, full_vals, full_cnt = ops.select_compact(g, row, col, thr)
    cap = int(full_cnt) // 2
    idx, vals, cnt = ops.select_compact(g, row, col, thr, capacity=cap)
    assert int(cnt) == int(full_cnt)          # true count survives
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.asarray(full_idx[:cap]))
    np.testing.assert_array_equal(np.asarray(vals),
                                  np.asarray(full_vals[:cap]))


def test_select_compact_agrees_with_fused_mask():
    """Scattering the compact buffers back reproduces the fused masked
    gradient — the two kernels are views of the same selection."""
    g = jax.random.normal(jax.random.PRNGKey(9), (64, 48))
    row, col = ref.channel_norms_ref(g)
    thr = jnp.median(row[:, None] + col[None, :])
    masked, kept = ops.scbf_select_fused(g, row, col, thr)
    idx, vals, cnt = ops.select_compact(g, row, col, thr)
    assert int(cnt) == int(kept)
    rebuilt = np.zeros(g.size, np.float32)
    n = int(cnt)
    rebuilt[np.asarray(idx[:n])] = np.asarray(vals[:n])
    np.testing.assert_allclose(rebuilt.reshape(g.shape),
                               np.asarray(masked, np.float32), rtol=1e-6)


@pytest.mark.parametrize("shape", [(16, 8), (512, 256), (1000, 77),
                                   (2048, 64), (37, 130)])
def test_apoz_sweep(shape):
    key = jax.random.PRNGKey(3)
    a = jax.nn.relu(jax.random.normal(key, shape))
    got = ops.apoz_counts(a)
    want = ref.apoz_counts_ref(a)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int32


def test_kernel_matches_core_selection():
    """The kernel path must agree with core/channels factored scoring on
    column scores (the output-channel convention)."""
    g = jax.random.normal(jax.random.PRNGKey(4), (64, 48))
    _, col = ops.channel_norms(g)
    from repro.core.channels import factored_scores
    _, scores = factored_scores([g])
    np.testing.assert_allclose(np.asarray(col), np.asarray(scores[0]),
                               rtol=1e-5)
