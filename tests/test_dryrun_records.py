"""Deliverable (e) gate: every (arch × shape × mesh) dry-run record on
disk must have compiled OK and fit in HBM.

The sweep itself runs as its own process (it needs 512 virtual devices
before jax init):  ``python -m repro.launch.dryrun --all --mesh single``
and ``--mesh multi``.  This test validates whatever records exist and
skips when the sweep hasn't been run (CI without the artifacts).
"""
import glob
import json
import os

import pytest

DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
RECORDS = sorted(glob.glob(os.path.join(DIR, "*.json")))

HBM_BYTES = 16e9  # TPU v5e


@pytest.mark.skipif(not RECORDS, reason="dry-run sweep not run")
@pytest.mark.parametrize("path", RECORDS, ids=[os.path.basename(p)
                                               for p in RECORDS])
def test_dryrun_record_ok(path):
    with open(path) as f:
        r = json.load(f)
    assert r["ok"], f"{r['arch']} {r['shape']} {r['mesh']}: " \
        f"{r.get('error', '')[:200]}"
    # per-device persistent state (param shards + inputs incl. caches)
    # must fit HBM.  Transient temp_size from the XLA:CPU module is only
    # a loose upper bound (the CPU backend neither fuses elementwise
    # chains nor schedules for working-set size the way the TPU backend
    # does), so it is reported in EXPERIMENTS.md but not gated here.
    mem = r["memory"]
    args = mem.get("argument_size_in_bytes", 0)
    assert args < HBM_BYTES, \
        f"state {args/1e9:.1f} GB exceeds v5e HBM"
    # roofline terms present and positive
    t = r["terms"]
    assert t["compute_s"] > 0
    assert t["dominant"] in ("compute_s", "memory_s", "collective_s")


@pytest.mark.skipif(not RECORDS, reason="dry-run sweep not run")
def test_sweep_coverage():
    """After the full sweep: 10 archs × 4 shapes × 2 meshes."""
    names = {os.path.basename(p) for p in RECORDS}
    if len(names) < 80:
        pytest.skip(f"partial sweep ({len(names)}/80 records)")
    from repro import configs
    from repro.config import INPUT_SHAPES
    for arch in configs.ASSIGNED:
        for shape in INPUT_SHAPES:
            for mesh in ("single", "multi"):
                assert f"{arch}_{shape}_{mesh}.json" in names
