# Developer entry points.  `make lint` is byte-for-byte the CI lint
# job's command (docs/STATIC_ANALYSIS.md §CI): all three static gates
# — tracelint, privlint, shapelint — in one merged run, pure ast, no
# JAX needed.
PY ?= python

.PHONY: lint test test-fast

lint:
	PYTHONPATH=src $(PY) -m repro.analysis src benchmarks examples \
	    --json-out lint-report.json

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow"
